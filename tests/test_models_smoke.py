"""Per-arch smoke tests (deliverable f): reduced family variant, one
forward + one train step on CPU, asserting output shapes + no NaNs."""
import functools

import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.models import forward, init_params
from repro.training import init_adamw, train_step

B, S = 2, 32


def test_forward_shapes_no_nan(arch_cfg):
    cfg = arch_cfg.reduced()
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, B, S)
    logits, aux, _ = forward(cfg, params, batch, mode="train", remat=False)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not jnp.isnan(logits).any()
    assert not jnp.isnan(aux)


def test_train_step_no_nan(arch_cfg):
    cfg = arch_cfg.reduced()
    params = init_params(cfg, jax.random.key(0))
    opt = init_adamw(params)
    batch = make_batch(cfg, B, S, labels=True)
    step = jax.jit(functools.partial(train_step, cfg))
    params2, opt2, metrics = step(params, opt, batch)
    assert float(metrics["loss"]) > 0
    assert not jnp.isnan(metrics["loss"])
    assert not jnp.isnan(metrics["grad_norm"])
    # params actually moved (skip zero-size stacks: patterns longer than
    # the reduced layer count leave empty scanned bodies)
    moved = any(
        a.size and float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved
