"""Perf levers (EXPERIMENTS.md §Perf) must not change model numerics:
sharding constraints are layout-only; parallel_block is the documented
PaLM-style math variant and is checked against its explicit formulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.models import decode_step, forward, init_cache, init_params
from repro.util import sharding_hints

B, S = 2, 32


def _fwd(cfg, params, batch, opts):
    mesh = make_local_mesh()
    with mesh, sharding_hints(batch_axes=("data",), model_axis="model",
                              opts=opts, batch_div=1):
        logits, aux, _ = forward(cfg, params, batch, mode="train",
                                 remat=False)
    return logits


@pytest.mark.parametrize("arch,opts", [
    ("grok-1-314b", {"moe_pin"}),
    ("granite-8b", {"attn_carry"}),
    ("granite-8b", {"bf16_ar"}),
])
def test_constraint_levers_preserve_numerics(arch, opts):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, B, S)
    base = forward(cfg, params, batch, mode="train", remat=False)[0]
    opt = _fwd(cfg, params, batch, frozenset(opts))
    np.testing.assert_allclose(np.asarray(opt), np.asarray(base),
                               atol=1e-5, rtol=1e-4)


def test_kv_seq_preserves_decode():
    cfg = get_config("granite-8b").reduced()
    params = init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg, B, S)
    cache = init_cache(cfg, B, S + 4)
    _, _, cache0 = forward(cfg, params, batch, mode="prefill", cache=cache)
    tok = {"tokens": batch["tokens"][:, -1:]}
    base, _ = decode_step(cfg, params, cache0, tok)
    mesh = make_local_mesh()
    with mesh, sharding_hints(opts=frozenset({"kv_seq"}), batch_div=1):
        opt, _ = decode_step(cfg, params, cache0, tok)
    np.testing.assert_allclose(np.asarray(opt), np.asarray(base),
                               atol=1e-5, rtol=1e-4)


def test_parallel_block_matches_explicit_formulation():
    """parallel_block's fused projection == x + attn(n1(x)) + mlp(n2(x))."""
    from repro.models import layers as L
    from repro.models.blocks import _attn_apply, apply_block, init_block

    cfg = get_config("granite-8b").reduced()
    p = init_block(cfg, "dense", jax.random.key(3), jnp.float32)
    x = jax.random.normal(jax.random.key(4), (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    mesh = make_local_mesh()
    with mesh, sharding_hints(opts=frozenset({"parallel_block"}),
                              batch_div=1):
        fused, _, _ = apply_block(cfg, "dense", p, x, pos, mode="train",
                                  cache=None, pos=jnp.zeros((), jnp.int32))

    h1 = L.apply_norm(cfg, p["norm1"], x)
    a, _ = _attn_apply(cfg, p["attn"], h1, pos, mode="train", cache=None,
                       pos=jnp.zeros((), jnp.int32), window=0, causal=True)
    h2 = L.apply_norm(cfg, p["norm2"], x)
    m = L.apply_mlp(cfg, p["mlp"], h2)
    want = x + a + m
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               atol=1e-4, rtol=1e-3)
