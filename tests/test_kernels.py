"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles,
executed with interpret=True (kernel bodies run on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk(key, shape, dtype):
    x = jax.random.normal(jax.random.key(key), shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("s", [128, 256, 512])
@pytest.mark.parametrize("d", [64, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(s, d, dtype, causal):
    b, h, kv = 2, 4, 2
    q = _mk(1, (b, s, h, d), dtype)
    k = _mk(2, (b, s, kv, d), dtype)
    v = _mk(3, (b, s, kv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    kk = jnp.repeat(k, h // kv, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vv = jnp.repeat(v, h // kv, axis=2).transpose(0, 2, 1, 3).reshape(b * h, s, d)
    qq = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    want = ref.ref_attention(qq, kk, vv, causal=causal)
    want = want.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("w", [128, 512])
@pytest.mark.parametrize("d", [64, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(w, d, dtype):
    b, h, kv = 2, 4, 2
    q = _mk(4, (b, 1, h, d), dtype)
    kc = _mk(5, (b, w, kv, d), dtype)
    vc = _mk(6, (b, w, kv, d), dtype)
    pos = jnp.asarray([w // 3, w], jnp.int32)  # one partial, one full cache
    out = ops.decode_attention(q, kc, vc, pos, interpret=True)
    kk = jnp.repeat(kc, h // kv, axis=2).transpose(0, 2, 1, 3).reshape(b * h, w, d)
    vv = jnp.repeat(vc, h // kv, axis=2).transpose(0, 2, 1, 3).reshape(b * h, w, d)
    qq = q.transpose(0, 2, 1, 3).reshape(b * h, 1, d)
    nv = jnp.repeat(jnp.minimum(pos, w), h)
    want = ref.ref_decode_attention(qq, kk, vv, nv)
    want = want.reshape(b, h, 1, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("sq", [4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_chunked(sq, dtype):
    """Chunked-prefill queries: per-query validity == causal-within-chunk.
    The S-query kernel call must match S separate 1-query calls."""
    b, h, kv, w, d = 2, 4, 2, 128, 64
    q = _mk(7, (b, sq, h, d), dtype)
    kc = _mk(8, (b, w, kv, d), dtype)
    vc = _mk(9, (b, w, kv, d), dtype)
    pos = jnp.asarray([40, w], jnp.int32)  # tokens written incl. the chunk
    out = ops.decode_attention(q, kc, vc, pos, interpret=True)
    kk = jnp.repeat(kc, h // kv, axis=2).transpose(0, 2, 1, 3).reshape(b * h, w, d)
    vv = jnp.repeat(vc, h // kv, axis=2).transpose(0, 2, 1, 3).reshape(b * h, w, d)
    qq = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    nv = jnp.repeat(jnp.minimum(pos, w), h)
    want = ref.ref_decode_attention(qq, kk, vv, nv)
    want = want.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])
    # row-by-row against single-query calls with shrinking validity
    for i in range(sq):
        one = ops.decode_attention(q[:, i:i + 1], kc, vc,
                                   pos - (sq - 1 - i), interpret=True)
        np.testing.assert_allclose(
            np.asarray(out[:, i:i + 1], np.float32),
            np.asarray(one, np.float32), atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("sq", [1, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention(sq, dtype):
    """Block-sparse paged kernel vs the gather-then-mask oracle: slots'
    pages are deliberately scattered/permuted through the pool, with one
    partially-valid slot and one slot whose table is fully resident."""
    b, h, kv, d = 2, 4, 2, 64
    ps, n_pages, pool_p = 16, 4, 12
    q = _mk(20, (b, sq, h, d), dtype)
    k_pool = _mk(21, (pool_p, ps, kv, d), dtype)
    v_pool = _mk(22, (pool_p, ps, kv, d), dtype)
    # non-trivial page assignment incl. shared trash page 0 entries
    table = jnp.asarray([[7, 3, 11, 0], [2, 9, 4, 6]], jnp.int32)
    pos = jnp.asarray([ps * 2 + 5, ps * 4], jnp.int32)  # partial + full
    out = ops.paged_decode_attention(q, k_pool, v_pool, table, pos,
                                     interpret=True)
    want = ref.ref_paged_decode_attention(q, k_pool, v_pool, table, pos)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_paged_kernel_matches_linear_decode_kernel():
    """A contiguous identity page table must reproduce the linear decode
    kernel exactly (the paged kernel is a superset)."""
    b, h, kv, d, ps, n_pages = 2, 4, 2, 64, 16, 8
    w = ps * n_pages
    q = _mk(23, (b, 1, h, d), jnp.float32)
    kc = _mk(24, (b, w, kv, d), jnp.float32)
    vc = _mk(25, (b, w, kv, d), jnp.float32)
    pos = jnp.asarray([50, w], jnp.int32)
    linear = ops.decode_attention(q, kc, vc, pos, interpret=True)
    # slot b's cache rows [j*ps, (j+1)*ps) live in pool page b*n_pages+j
    k_pool = kc.reshape(b * n_pages, ps, kv, d)
    v_pool = vc.reshape(b * n_pages, ps, kv, d)
    table = jnp.arange(b * n_pages, dtype=jnp.int32).reshape(b, n_pages)
    paged = ops.paged_decode_attention(q, k_pool, v_pool, table, pos,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(linear),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("s", [128, 384])
@pytest.mark.parametrize("l", [128, 256])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_rglru_scan(s, l, dtype):
    b = 2
    a = jax.random.uniform(jax.random.key(7), (b, s, l), minval=0.7,
                           maxval=0.999).astype(dtype)
    x = (_mk(8, (b, s, l), dtype) * 0.1).astype(dtype)
    h0 = _mk(9, (b, l), dtype)
    y, hT = ops.rglru_scan(a, x, h0, interpret=True)
    ry, rhT = ref.ref_rglru_scan(a, x, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ry), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(rhT), atol=1e-4,
                               rtol=1e-4)


def test_rglru_scan_matches_naive_loop():
    b, s, l = 1, 64, 128
    a = jax.random.uniform(jax.random.key(1), (b, s, l), minval=0.5, maxval=1.0)
    x = jax.random.normal(jax.random.key(2), (b, s, l)) * 0.2
    h0 = jnp.zeros((b, l))
    y, hT = ops.rglru_scan(a, x, h0, interpret=True)
    h = np.zeros((b, l), np.float32)
    an, xn = np.asarray(a), np.asarray(x)
    for t in range(s):
        h = an[:, t] * h + xn[:, t]
        np.testing.assert_allclose(np.asarray(y[:, t]), h, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), h, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (256, 128, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int8_matmul(m, k, n, dtype):
    x = _mk(10, (m, k), dtype)
    w = _mk(11, (k, n), jnp.float32)
    wq, sc = ops.quantize_int8(w)
    out = ops.int8_matmul(x, wq, sc, interpret=True)
    want = ref.ref_int8_matmul(x, wq, sc)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=5e-2, rtol=5e-2)


def test_int8_quantization_error_bounded():
    w = jax.random.normal(jax.random.key(3), (256, 256))
    wq, sc = ops.quantize_int8(w)
    deq = np.asarray(wq, np.float32) * np.asarray(sc)[None, :]
    rel = np.abs(deq - np.asarray(w)).max() / np.abs(np.asarray(w)).max()
    assert rel < 0.01  # <1% of max magnitude per channel


@pytest.mark.parametrize("v", [128, 500])
@pytest.mark.parametrize("seed", [0, 1])
def test_topk_sample_matches_oracle(v, seed):
    """Radix-select kernel vs the sort-based oracle: exact token equality
    (same noise input) across per-row k / temperature mixes."""
    b = 6
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((b, v)) * 3, jnp.float32)
    k = jnp.asarray(rng.integers(1, v + 1, b), jnp.int32)
    temp = jnp.asarray(rng.uniform(0.2, 2.0, b), jnp.float32)
    u = jnp.asarray(rng.uniform(0, 1, (b, v)), jnp.float32)
    got = ops.topk_sample(logits, k, temp, u, interpret=True)
    want = ref.ref_topk_sample(logits, k, temp, u)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_topk_sample_value_ties_keep_oracle_semantics():
    """Duplicated logit values straddling the k-th rank: the kernel's
    radix-select threshold keeps every tied entry, exactly like the
    oracle's ``x >= kth`` mask."""
    rng = np.random.default_rng(3)
    logits = jnp.asarray(np.repeat(rng.standard_normal((2, 32)), 2, axis=1),
                         jnp.float32)
    k = jnp.asarray([3, 7], jnp.int32)
    temp = jnp.ones((2,), jnp.float32)
    u = jnp.asarray(rng.uniform(0, 1, logits.shape), jnp.float32)
    got = ops.topk_sample(logits, k, temp, u, interpret=True)
    want = ref.ref_topk_sample(logits, k, temp, u)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_topk_sample_k1_is_greedy():
    """k=1 restricts the distribution to the (unique) argmax: the draw is
    deterministic no matter the noise."""
    rng = np.random.default_rng(4)
    logits = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    k = jnp.ones((4,), jnp.int32)
    temp = jnp.asarray([0.3, 0.7, 1.0, 2.0], jnp.float32)
    for s in range(3):
        u = jnp.asarray(rng.uniform(0, 1, logits.shape), jnp.float32)
        got = ops.topk_sample(logits, k, temp, u, interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(jnp.argmax(logits, -1)))


def test_topk_sample_respects_the_mask():
    """Across many draws every sampled token is inside the top-k set and
    the model-layout twin (layers.process_logits) agrees on that set."""
    from repro.models.layers import process_logits

    rng = np.random.default_rng(5)
    b, v, kk = 3, 96, 8
    logits = jnp.asarray(rng.standard_normal((b, v)) * 2, jnp.float32)
    k = jnp.full((b,), kk, jnp.int32)
    temp = jnp.full((b,), 0.9, jnp.float32)
    allowed = np.asarray(process_logits(
        logits, temp, k, jnp.ones((b,), jnp.float32))) > -np.inf
    assert (allowed.sum(axis=1) == kk).all()
    for s in range(8):
        u = jnp.asarray(rng.uniform(0, 1, (b, v)), jnp.float32)
        tok = np.asarray(ops.topk_sample(logits, k, temp, u, interpret=True))
        assert all(allowed[i, tok[i]] for i in range(b))
